"""Render EXPERIMENTS.md §Roofline from the dry-run JSONs, plus the
kernel-entry roofline table from the analytic cost model
(repro/perf/cost_model.py — DESIGN.md §11)."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path("experiments/dryrun")


def kernel_table_markdown(m: int = 4096, c: int = 21, bits: int = 4,
                          backend: str = "tpu") -> str:
    """Analytic roofline of every dispatch-registry entry at one
    representative shape — same record fields as the dry-run table, no
    hardware needed (estimates, clearly labelled as such)."""
    from repro.perf import autotune, cost_model
    out = ["| entry | block_m | dominant | compute s | memory s "
           "| AI (flop/B) | est s | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for w in autotune.default_workloads(m=m, c=c, bits=bits):
        r = cost_model.roofline_estimate(w, backend=backend)
        out.append(
            f"| {w.entry} | {r['block_m']} | {r['dominant']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['arithmetic_intensity']:.1f} | {r['estimated_s']:.3e} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def load_records():
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:
            pass
    return recs


def table_markdown(mesh: str = "single") -> str:
    rows = [r for r in load_records() if r.get("mesh") == mesh and r.get("ok")]
    out = ["| arch | shape | dominant | compute s | memory s | collective s | "
           "MODEL_FLOPs | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['dominant']} "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['model_flops_global']:.2e} "
            f"| {rf['useful_flops_ratio']:.3f} "
            f"| {rf['roofline_fraction']:.3f} |")
    return "\n".join(out)


def compare_markdown(mesh: str = "single",
                     baseline_dir: str = "experiments/dryrun_baseline") -> str:
    """§Perf before/after: naive-sharding baseline vs optimized records."""
    base = {}
    for p in sorted(Path(baseline_dir).glob(f"*__{mesh}.json")):
        try:
            d = json.loads(p.read_text())
            if d.get("ok"):
                base[(d["arch"], d["shape"])] = d["roofline"]
        except Exception:
            pass
    out = ["| arch | shape | term | baseline s | optimized s | gain |",
           "|---|---|---|---|---|---|"]
    for r in load_records():
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        key = (r["arch"], r["shape"])
        if key not in base:
            continue
        b, o = base[key], r["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            gain = b[term] / max(o[term], 1e-12)
            if gain >= 1.5 or gain <= 0.67:
                out.append(f"| {key[0]} | {key[1]} | {term[:-2]} "
                           f"| {b[term]:.3e} | {o[term]:.3e} "
                           f"| {gain:.1f}x |")
    return "\n".join(out)


def summary_line() -> str:
    rows = [r for r in load_records() if r.get("ok")]
    if not rows:
        return "no dryrun records yet"
    n = len(rows)
    doms = {}
    for r in rows:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    worst = min(rows, key=lambda r: r["roofline"]["roofline_fraction"])
    return (f"cells={n} dominant={doms} worst="
            f"{worst['arch']}/{worst['shape']}/{worst['mesh']}"
            f"@{worst['roofline']['roofline_fraction']:.3f}")


if __name__ == "__main__":
    print(table_markdown("single"))
    print()
    print(kernel_table_markdown())
    print()
    print(summary_line())
