"""Reproductions of the paper's tables/figures (one function per table).

Physical µm²/nW values are the paper's own Spectre/PragmatIC measurements
(constants in core.area) — this container has no analog simulator; what the
framework *computes* are the transistor-count area models, the pruning
design rules and the in-training optimization, which is everything the
paper's methodology consumes (DESIGN.md §6.1).
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core import area, search
from repro.data import tabular

OUT = Path("experiments/paper")


def table3() -> dict:
    """Table 3: 3-bit flash ADC area/power split (encoder dominates power —
    the motivation for the encoder-free binary-search design)."""
    t = area.PAPER_TABLE3
    ladder, enc = t["ladder_comparators"], t["encoder_7to3"]
    a_tot = ladder["area_um2"] + enc["area_um2"]
    p_tot = ladder["power_nw"] + enc["power_nw"]
    model_enc_share = area.flash_encoder_tc(3) / area.flash_full_tc(3)
    return {
        "paper_area_split": {"ladder+comp": ladder["area_um2"],
                             "encoder": enc["area_um2"],
                             "encoder_share": enc["area_um2"] / a_tot},
        "paper_power_split": {"ladder+comp": ladder["power_nw"],
                              "encoder": enc["power_nw"],
                              "encoder_share": enc["power_nw"] / p_tot},
        "model_encoder_tc_share": model_enc_share,
    }


def table4() -> dict:
    """Table 4: full-ADC comparison. Paper reports µm²/nW; the framework's
    TC model must reproduce the paper's *ratios* (2x vs baseline, 5.4x vs
    flash at 3-bit in area; our TC ratios are the transistor-count analogue
    used by the pruning search)."""
    rows = {}
    for bits in (2, 3, 4):
        flash = area.flash_full_tc(bits)
        base = area.baseline_binary_tc(bits)
        ours = area.ours_full_tc(bits)
        rows[bits] = {
            "flash_tc": flash, "baseline_tc": base, "ours_tc": ours,
            "tc_ratio_flash_over_ours": round(flash / ours, 2),
            "tc_ratio_base_over_ours": round(base / ours, 2),
        }
        if ("flash", bits) in area.PAPER_TABLE4:
            p = area.PAPER_TABLE4
            rows[bits]["paper_area_ratio_flash_over_ours"] = round(
                p[("flash", bits)]["area_um2"]
                / p[("binary_ours", bits)]["area_um2"], 2)
            rows[bits]["paper_area_ratio_base_over_ours"] = round(
                p[("binary_baseline", bits)]["area_um2"]
                / p[("binary_ours", bits)]["area_um2"], 2)
            rows[bits]["paper_power_ratio_flash_over_ours"] = round(
                p[("flash", bits)]["power_nw"]
                / p[("binary_ours", bits)]["power_nw"], 2)
    return rows


def _search_dataset(ds: str, bits: int, *, pop=24, gens=10, steps=300,
                    seed=0):
    data = tabular.make_dataset(ds, seed=seed)
    spec = tabular.SPECS[ds]
    sizes = (spec.features, spec.hidden, spec.classes)
    cfg = search.SearchConfig(bits=bits, pop_size=pop, generations=gens,
                              train_steps=steps, seed=seed)
    base = search.full_adc_baseline(data, sizes, cfg)
    pg, pf, decode = search.run_search(data, sizes, cfg)
    order = np.argsort(pf[:, 0])
    pf = pf[order]
    pg = pg[order]
    # "best accuracy" pareto point (paper Table 5 policy)
    best = pf[0]
    # area of that point in TC (denormalise)
    flash_full = area.flash_full_tc(bits) * spec.features
    return {
        "dataset": ds, "bits": bits,
        "baseline_acc": round(base["accuracy"], 4),
        "pruned_acc": round(1.0 - float(best[0]), 4),
        "flash_tc": base["area_flash_tc"],
        "binary_tc": base["area_binary_ours_tc"],
        "pruned_tc": int(round(float(best[1]) * flash_full)),
        "pareto": [[round(1 - float(a), 4), int(round(float(r) * flash_full))]
                   for a, r in pf],
    }


def table5(datasets=("seeds", "mammographic", "cardio", "whitewine"),
           bits_list=(2, 3, 4), fast: bool = True) -> dict:
    """Table 5: accuracy & ADC transistor count, Flash -> Binary -> Pruned.
    fast=True shrinks the GA for the benchmark harness; fig4/--full runs
    the paper-scale search."""
    kw = dict(pop=16, gens=6, steps=200) if fast else dict(pop=32, gens=16,
                                                           steps=400)
    per_bits = {}
    for bits in bits_list:
        rows = [_search_dataset(ds, bits, **kw) for ds in datasets]
        agg = {
            "acc_baseline_mean": round(float(np.mean(
                [r["baseline_acc"] for r in rows])) * 100, 1),
            "acc_pruned_mean": round(float(np.mean(
                [r["pruned_acc"] for r in rows])) * 100, 1),
            "flash_tc": int(np.sum([r["flash_tc"] for r in rows])),
            "binary_tc": int(np.sum([r["binary_tc"] for r in rows])),
            "pruned_tc": int(np.sum([r["pruned_tc"] for r in rows])),
        }
        agg["gain_flash_to_binary_pct"] = round(
            100 * (1 - agg["binary_tc"] / agg["flash_tc"]), 1)
        agg["gain_binary_to_pruned_pct"] = round(
            100 * (1 - agg["pruned_tc"] / agg["binary_tc"]), 1)
        agg["gain_flash_to_pruned_x"] = round(
            agg["flash_tc"] / max(agg["pruned_tc"], 1), 2)
        paper = area.PAPER_TABLE5.get(bits, {})
        per_bits[bits] = {"rows": rows, "aggregate": agg, "paper": paper}
    return per_bits


def fig4(datasets=("seeds", "mammographic", "cardio", "whitewine"),
         bits_list=(2, 3, 4), fast: bool = True) -> dict:
    """Figure 4: accuracy-vs-normalized-area pareto fronts per dataset."""
    kw = dict(pop=16, gens=6, steps=200) if fast else dict(pop=32, gens=16,
                                                           steps=400)
    out = {}
    for ds in datasets:
        for bits in bits_list:
            r = _search_dataset(ds, bits, **kw)
            flash_full = area.flash_full_tc(bits) * tabular.SPECS[ds].features
            out[f"{ds}_{bits}bit"] = {
                "pareto_acc_area": r["pareto"],
                "norm_area": [round(a / flash_full, 4)
                              for _, a in r["pareto"]],
                "full_binary_acc": r["baseline_acc"],
            }
    return out


def save(name: str, obj) -> Path:
    OUT.mkdir(parents=True, exist_ok=True)
    p = OUT / f"{name}.json"
    p.write_text(json.dumps(obj, indent=1))
    return p
